"""CLI driver for repro-lint: ``python -m tools.repro_lint``.

Exit status is the CI gate (DESIGN.md §8.6): 0 when every finding is
grandfathered and no baseline entry is stale, 1 otherwise. ``--report``
writes the full findings list (baselined or not) to a file for the CI
artifact, so a red run ships its evidence; ``--sarif`` writes the same
list as SARIF 2.1.0 for code-scanning upload, and
``--github-annotations`` prints ``::error`` workflow commands for new
findings so they land as PR-diff annotations.
"""

from __future__ import annotations

import argparse
import pathlib
import sys

from tools.repro_lint.baseline import (diff_against_baseline, load_baseline,
                                       save_baseline)
from tools.repro_lint.checkers import CHECKERS, run_checkers
from tools.repro_lint.sarif import github_annotation, render_sarif


def _repo_root() -> pathlib.Path:
    # tools/repro_lint/cli.py -> repo root is two parents up from tools/.
    return pathlib.Path(__file__).resolve().parent.parent.parent


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro_lint",
        description="repo-specific determinism + cross-module contract "
                    "static analysis (RL001-RL010; see DESIGN.md §8)")
    parser.add_argument("--root", type=pathlib.Path, default=None,
                        help="repo root to scan (default: auto-detected)")
    parser.add_argument("--baseline", type=pathlib.Path, default=None,
                        help="baseline file (default: "
                             "tools/repro_lint/baseline.txt under root)")
    parser.add_argument("--update-baseline", action="store_true",
                        help="rewrite the baseline from this run's "
                             "findings and exit 0")
    parser.add_argument("--report", type=pathlib.Path, default=None,
                        help="also write every finding (new or "
                             "grandfathered) to this file")
    parser.add_argument("--sarif", type=pathlib.Path, default=None,
                        help="also write findings as SARIF 2.1.0 to "
                             "this file")
    parser.add_argument("--github-annotations", action="store_true",
                        help="print ::error workflow commands for new "
                             "findings (GitHub PR annotations)")
    args = parser.parse_args(argv)

    root = (args.root or _repo_root()).resolve()
    baseline_path = args.baseline or root / "tools/repro_lint/baseline.txt"

    findings = run_checkers(root, CHECKERS)

    if args.report is not None:
        args.report.parent.mkdir(parents=True, exist_ok=True)
        args.report.write_text(
            "".join(f.render() + "\n" for f in findings))

    if args.update_baseline:
        save_baseline(baseline_path, findings)
        print(f"repro-lint: baseline updated with {len(findings)} "
              f"finding(s) -> {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new, stale = diff_against_baseline(findings, baseline)
    new_keys = frozenset(f.key() for f in new)

    if args.sarif is not None:
        args.sarif.parent.mkdir(parents=True, exist_ok=True)
        args.sarif.write_text(render_sarif(findings, CHECKERS, new_keys))

    for f in new:
        print(f.render())
        if args.github_annotations:
            print(github_annotation(f))
    for key in stale:
        print(f"{key}: stale baseline entry (finding no longer "
              f"produced; run --update-baseline)")

    grandfathered = len(findings) - len(new)
    status = "FAIL" if (new or stale) else "ok"
    print(f"repro-lint: {status} — {len(new)} new finding(s), "
          f"{len(stale)} stale baseline entr(y/ies), "
          f"{grandfathered} grandfathered, {len(CHECKERS)} checkers")
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
