"""Entry point: ``python -m tools.repro_lint`` (== ``make lint-deep``)."""

import sys

from tools.repro_lint.cli import main

sys.exit(main())
