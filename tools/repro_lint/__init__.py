"""repro-lint — repo-specific determinism & simulated-clock static analysis.

The repo's headline guarantees are *determinism* guarantees: bit-identical
lanes when a feature is disabled, NaN-safe shed accounting, busy-time
conservation on a simulated clock. The tests enforce them dynamically, but
a test cannot see a *new* call site that quietly breaks the contract — a
``time.time()`` on the simulated clock, a global ``np.random.*`` draw, a
``set`` iterated into an array. repro-lint makes those disciplines
machine-checked (DESIGN.md §8):

========  ==========================================================
checker   invariant enforced
========  ==========================================================
RL001     simulated-clock purity — no wall-clock reads in
          ``src/repro/{flashsim,core,serving}/`` (DESIGN.md §8.1)
RL002     RNG discipline — no global ``np.random.*`` / module-level
          ``random`` state in ``src/repro/`` (DESIGN.md §8.2)
RL003     ordering hazards — no set/dict-view iteration feeding
          order-sensitive numeric sinks (DESIGN.md §8.3)
RL004     units discipline — no mixing of ``_us``/``_bytes``/``_pages``
          quantities or bare literals added to ``_us`` (DESIGN.md §8.4)
RL005     API discipline — ``jax.experimental`` only via ``compat.py``,
          engines only via ``serving/deployment.py`` (DESIGN.md §8.5)
RL006     NaN contract — reductions over latency/completion arrays are
          nan* variants or finite-masked (DESIGN.md §8.7)
RL007     trace-counter conservation — gather/merge/summarize functions
          thread every numeric trace field (DESIGN.md §8.8)
RL008     config round-trip — DeploymentConfig-family fields survive
          to_dict/from_dict, legacy blobs keep loading (DESIGN.md §8.9)
RL009     Pallas DMA discipline — every .start() awaited, kernel arity
          matches specs, no late-bound loop vars (DESIGN.md §8.10)
RL010     cross-module API discipline — RL005's contracts under
          aliasing, via the project symbol graph (DESIGN.md §8.11)
========  ==========================================================

RL006–RL010 are *cross-module* rules: they query a project-wide symbol
graph (``symbols.ProjectGraph`` — dataclass fields, call edges, alias
maps) built once per run and cached on disk keyed by source hash.

Run via ``make lint-deep`` (→ ``python -m tools.repro_lint``). Findings
not yet burned down live in ``tools/repro_lint/baseline.txt``; CI fails
on *new* findings and on stale baseline entries (DESIGN.md §8.6). The
shipped baseline is empty — every finding the ten rules produce on the
tree has been fixed or carries a reviewed config/pragma exemption.
"""

from tools.repro_lint.base import Finding, iter_pragmas
from tools.repro_lint.baseline import (load_baseline, save_baseline,
                                       diff_against_baseline)
from tools.repro_lint.checkers import CHECKERS, run_checkers
from tools.repro_lint.cli import main
from tools.repro_lint.sarif import render_sarif, to_sarif
from tools.repro_lint.symbols import (ProjectGraph, build_graph,
                                      is_numeric_annotation, module_name,
                                      summarize_module)

__all__ = [
    "CHECKERS",
    "Finding",
    "ProjectGraph",
    "build_graph",
    "diff_against_baseline",
    "is_numeric_annotation",
    "iter_pragmas",
    "load_baseline",
    "main",
    "module_name",
    "render_sarif",
    "run_checkers",
    "save_baseline",
    "summarize_module",
    "to_sarif",
]
