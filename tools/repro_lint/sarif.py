"""SARIF 2.1.0 emitter for repro-lint findings (DESIGN.md §8.6).

One run object, one rule per checker (id, one-line invariant as the
short description), one result per finding. The output is the minimal
valid subset GitHub code scanning accepts, so ``make lint-deep`` CI
runs can upload the file and get PR-diff annotations without any extra
tooling. Grandfathered findings are emitted with ``"baseline":
"unchanged"`` so the viewer can filter them; new findings are
``"new"``.
"""

from __future__ import annotations

import json

from tools.repro_lint.base import Checker, Finding

SARIF_VERSION = "2.1.0"
SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
                "master/Schemata/sarif-schema-2.1.0.json")


def to_sarif(findings: list[Finding], checkers: tuple[Checker, ...],
             new_keys: frozenset[str] = frozenset()) -> dict:
    """Build the SARIF log dict (caller serialises)."""
    rules = [{
        "id": c.CHECKER_ID,
        "name": type(c).__name__,
        "shortDescription": {"text": c.INVARIANT or c.CHECKER_ID},
    } for c in checkers]
    rule_index = {c.CHECKER_ID: i for i, c in enumerate(checkers)}
    results = []
    for f in findings:
        results.append({
            "ruleId": f.checker_id,
            "ruleIndex": rule_index.get(f.checker_id, -1),
            "level": "error",
            "message": {"text": f.message},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {"uri": f.path,
                                         "uriBaseId": "SRCROOT"},
                    "region": {"startLine": f.line},
                },
            }],
            "baselineState": ("new" if f.key() in new_keys
                              else "unchanged"),
        })
    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [{
            "tool": {"driver": {
                "name": "repro-lint",
                "informationUri": "DESIGN.md",
                "rules": rules,
            }},
            "results": results,
            "originalUriBaseIds": {"SRCROOT": {"uri": "file:///"}},
        }],
    }


def render_sarif(findings: list[Finding], checkers: tuple[Checker, ...],
                 new_keys: frozenset[str] = frozenset()) -> str:
    return json.dumps(to_sarif(findings, checkers, new_keys), indent=2)


def github_annotation(finding: Finding) -> str:
    """One ``::error`` workflow command — GitHub turns these into
    PR-diff annotations when printed from a job step."""
    msg = finding.message.replace("%", "%25").replace("\r", "%0D") \
                         .replace("\n", "%0A")
    return (f"::error file={finding.path},line={finding.line},"
            f"title=repro-lint {finding.checker_id}::{msg}")
