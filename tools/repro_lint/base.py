"""Shared repro-lint plumbing: findings, checker protocol, skip pragmas.

A checker is a class with a ``CHECKER_ID``, a one-line ``INVARIANT`` (its
DESIGN.md §8 anchor lives in the class docstring), and a
``check(path, tree, source) -> list[Finding]`` method. Checkers are pure
AST passes — they never import the code under analysis, so a broken or
jax-less tree still lints.

Inline exemptions (DESIGN.md §8.6): a finding whose source line (or the
line above it) carries ``# repro-lint: skip[RL00x]`` is suppressed for
that checker id; a bare ``# repro-lint: skip`` suppresses every checker
on that line. Pragmas are for reviewed false positives — genuine
violations get fixed, not skipped.
"""

from __future__ import annotations

import ast
import dataclasses
import re

PRAGMA_RE = re.compile(r"#\s*repro-lint:\s*skip(?:\[([A-Z0-9,\s]+)\])?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    path: str            # repo-relative, posix separators
    line: int            # 1-based
    checker_id: str      # e.g. "RL001"
    message: str

    def key(self) -> str:
        """Stable identity used by the baseline (message excluded so
        wording tweaks don't churn baseline files)."""
        return f"{self.path}:{self.line}:{self.checker_id}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.checker_id} {self.message}"


def iter_pragmas(source: str) -> dict[int, frozenset[str] | None]:
    """Map line number -> skipped checker ids (``None`` = skip all).

    A pragma applies to its own line and, when it is the only thing on
    its line (a comment line), to the following line as well.
    """
    out: dict[int, frozenset[str] | None] = {}
    for i, text in enumerate(source.splitlines(), 1):
        m = PRAGMA_RE.search(text)
        if not m:
            continue
        ids = (frozenset(s.strip() for s in m.group(1).split(","))
               if m.group(1) else None)
        out[i] = ids
        if text.lstrip().startswith("#"):
            out[i + 1] = ids
    return out


def apply_pragmas(findings: list[Finding], source: str) -> list[Finding]:
    """Drop findings suppressed by an inline ``repro-lint: skip`` pragma."""
    pragmas = iter_pragmas(source)
    if not pragmas:
        return findings
    kept = []
    for f in findings:
        ids = pragmas.get(f.line, frozenset())
        if ids is None or (ids and f.checker_id in ids):
            continue
        kept.append(f)
    return kept


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class Checker:
    """Base class; subclasses set CHECKER_ID/INVARIANT and visit the AST."""

    CHECKER_ID = "RL000"
    INVARIANT = ""
    # Cross-module checkers (RL006+) set this; the runner then builds the
    # project symbol graph once per run and injects it via set_graph
    # before any check() call. Fixture runs get a single-file graph.
    NEEDS_GRAPH = False

    def set_graph(self, graph) -> None:
        self.graph = graph

    def applies_to(self, path: str) -> bool:
        """Whether ``path`` (repo-relative posix) is in this checker's
        scope. Overridden via config-injected include/exclude prefixes."""
        raise NotImplementedError

    def check(self, path: str, tree: ast.AST,
              source: str) -> list[Finding]:
        raise NotImplementedError

    def finding(self, path: str, node: ast.AST, message: str) -> Finding:
        return Finding(path=path, line=getattr(node, "lineno", 1),
                       checker_id=self.CHECKER_ID, message=message)


def path_in_scope(path: str, include: tuple[str, ...],
                  exclude: tuple[str, ...] = ()) -> bool:
    """Prefix-based scope test over repo-relative posix paths."""
    if any(path == e or path.startswith(e.rstrip("/") + "/")
           for e in exclude):
        return False
    return any(path == i or path.startswith(i.rstrip("/") + "/")
               for i in include)
