"""End-to-end driver: train a ~100M-parameter DLRM with the RecFlash layout.

Runs a few hundred steps of CTR training on synthetic Criteo-like data with
the frequency-remapped tables (AF+PD), row-wise adagrad on the tables,
AdamW on the MLPs, and the fault-tolerant TrainLoop (atomic checkpoints +
resume). Identical to:

    PYTHONPATH=src python -m repro.launch.train --model dlrm --steps 300

This is the paper's offline phase + training stage (Fig. 8) end to end.
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--model", "dlrm", "--steps", "300",
                "--batch", "256", "--ckpt-dir", "/tmp/recflash_dlrm_ckpt",
                *sys.argv[1:]]
    raise SystemExit(main())
