"""Online-training simulation: a week of serving with popularity drift.

The paper's Fig. 14 story in miniature — daily traffic drifts (new items
become hot), the threshold trigger (top-5%, 0.3% portion) watches the
online window, and when it fires the Algorithm-1 adaptive remap re-sorts
ONLY the hot region of the hash table and rewrites only those rows. One
``Deployment`` owns both policy lanes; ``step_day`` serves the day's
traffic and evaluates the trigger. Printed per day: serving latency,
whether training triggered, and the remap cost actually charged.

    PYTHONPATH=src python examples/online_adaptive_remap.py
"""

from repro.core.engine import TableSpec
from repro.core.freq import AccessStats
from repro.data.criteo import CriteoSpec, CriteoDayStream
from repro.serving import Deployment, DeploymentConfig, TriggerConfig

N_DAYS = 7
N_ROWS = 100_000
DAILY = 2000           # inferences/day (scaled)

spec = CriteoSpec("demo", n_days=N_DAYS, rows_per_field=N_ROWS,
                  drift_frac=0.05)
stream = CriteoDayStream(spec, seed=0)

# offline phase: sample the training distribution, build the layout
counts = stream.sample_training_stats(20_000)
n_tables = 8
stats = [AccessStats(counts[t]) for t in range(n_tables)]

dep = Deployment(DeploymentConfig(
    tables=[TableSpec(N_ROWS, 128) for _ in range(n_tables)], part="TLC",
    policies=("rmssd", "recflash"), hot_frac=0.05,
    trigger=TriggerConfig("threshold", top_frac=0.05, portion=0.003)),
    sample_stats=stats)

print(f"{'day':>4} {'rmssd (ms)':>12} {'recflash (ms)':>14} "
      f"{'gain':>7} {'trained?':>9} {'remap cost (ms)':>16}")
cum_rf, cum_base = 0.0, 0.0
for day in range(N_DAYS):
    tb, rows, _ = stream.day_batch(day, DAILY)
    sel = tb < n_tables
    tb, rows = tb[sel], rows[sel]
    day_res = dep.step_day(day, tb, rows)
    r_base = day_res["rmssd"].inference
    r_rf = day_res["recflash"].inference
    log = day_res["recflash"].remap
    remap_ms = log.remap_latency_us / 1e3 if log else 0.0
    cum_base += r_base.latency_us / 1e3
    cum_rf += r_rf.latency_us / 1e3 + remap_ms
    print(f"{day:>4} {r_base.latency_us / 1e3:>12.1f} "
          f"{r_rf.latency_us / 1e3:>14.1f} "
          f"{1 - r_rf.latency_us / r_base.latency_us:>6.1%} "
          f"{'yes' if log else 'no':>9} {remap_ms:>16.2f}")
    if log:
        rep = log.update_report
        print(f"     -> adaptive remap: {rep.n_inserted_hot} new hot keys, "
              f"{rep.n_remapped} rows rewritten "
              f"({rep.n_remapped / (n_tables * N_ROWS):.2%} of the store), "
              f"{rep.n_comparisons} comparator ops")
    stream.advance_day()

print(f"\ncumulative: rmssd {cum_base:.1f} ms, recflash {cum_rf:.1f} ms "
      f"(incl. remap) -> {1 - cum_rf / cum_base:.1%} reduction")
