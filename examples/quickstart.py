"""Quickstart: the RecFlash idea in 60 lines.

1. Generate a skewed embedding-access trace (the recommendation workload).
2. Build the frequency statistics from a sampled sweep (offline phase).
3. Compare NAND access policies: RecSSD / RM-SSD / RecFlash (AF+PD+P$).
4. Run the TPU half: the same statistics drive the two-tier Pallas SLS
   kernel (hot prefix pinned in VMEM, cold rows gathered from HBM).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.engine import TableSpec
from repro.core.freq import AccessStats
from repro.data.tracegen import generate_trace
from repro.embedding.layout import RemapSpec, remap_table
from repro.kernels import ops
from repro.serving import Deployment, DeploymentConfig

N_ROWS, DIM = 100_000, 32

# 1. workload: Zipf-skewed lookups, high locality (K=0 -> 8% unique rate)
sample = generate_trace(N_ROWS, 20_000, k=0.0, seed=1)   # offline sample
trace = generate_trace(N_ROWS, 20_000, k=0.0, seed=2)    # serving traffic

# 2. offline phase: access counts -> frequency stats
stats = AccessStats.from_trace(sample, N_ROWS)
print(f"unique-access rate: {stats.unique_access_rate():.1%} "
      f"(top-1% rows absorb "
      f"{np.sort(stats.counts)[::-1][:N_ROWS // 100].sum() / stats.counts.sum():.0%} of traffic)")

# 3. storage half: one Deployment = one engine lane per policy
print(f"\nTLC NAND, {len(trace):,} lookups:")
dep = Deployment(DeploymentConfig(
    tables=[TableSpec(n_rows=N_ROWS, vec_bytes=DIM * 4)], part="TLC"),
    sample_stats=[stats])
tb = np.zeros_like(trace)
for policy in dep.cfg.policies:
    r = dep.engines[policy].serve(tb, trace)
    print(f"  {policy:10s} latency {r.latency_us / 1e3:9.1f} ms   "
          f"page reads {r.n_page_reads:6d}   "
          f"cache hits {r.n_cache_hits:6d}   "
          f"energy {r.energy_uj / 1e3:8.1f} mJ")

# 4. compute half: two-tier SLS kernel on the remapped table
spec = RemapSpec.from_counts(stats.counts, hot_frac=0.01)
table = jax.random.normal(jax.random.PRNGKey(0), (N_ROWS, DIM))
stored = remap_table(table, spec)
hot, cold = stored[:spec.hot_size], stored[spec.hot_size:]

bags = trace[:4096].reshape(512, 8)                      # 512 bags x 8
ranks = jnp.take(jnp.asarray(spec.rank_of), jnp.asarray(bags), axis=0)
out = ops.recflash_sls(hot, cold, ranks.astype(jnp.int32))
ref = ops.sls_ref(hot, cold, ranks.astype(jnp.int32))
hot_frac_hits = float((ranks < spec.hot_size).mean())
print(f"\nPallas two-tier SLS: {out.shape} bags, "
      f"{hot_frac_hits:.1%} of lookups served from the VMEM hot tier, "
      f"max |err| vs oracle = {float(jnp.abs(out - ref).max()):.2e}")
